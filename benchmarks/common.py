"""Shared benchmark helpers."""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Tuple, Union

Row = Tuple[str, float, str]   # (name, us_per_call, derived)


def timed(fn: Callable, *args, n: int = 3, **kw):
    fn(*args, **kw)                      # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / n
    return out, dt * 1e6                 # us


def fmt_rows(rows: List[Row]) -> str:
    return "\n".join(f"{n},{u:.1f},{d}" for n, u, d in rows)


def parse_derived(derived: str) -> Dict[str, Union[float, str]]:
    """'a=12;b=3.4x;note' -> {'a': 12.0, 'b': 3.4, 'note': 'note'}.

    Values parse as floats (a trailing 'x' multiplier is stripped);
    anything else stays a string, bare fragments key themselves.
    """
    out: Dict[str, Union[float, str]] = {}
    for part in filter(None, (p.strip() for p in derived.split(";"))):
        key, sep, val = part.partition("=")
        if not sep:
            out[key] = key
            continue
        try:
            out[key] = float(val[:-1] if val.endswith("x") else val)
        except ValueError:
            out[key] = val
    return out


def bench_json(suite: str, rows: List[Row], elapsed_s: float) -> dict:
    """Machine-readable suite result (one BENCH_<suite>.json per suite):
    us/call (us/round for the round suites) plus every derived metric —
    rounds/sec included — parsed into numbers, so the perf trajectory is
    diffable across PRs."""
    return {
        "suite": suite,
        "elapsed_s": round(elapsed_s, 3),
        "rows": [{"name": n, "us_per_call": round(u, 3),
                  "derived": parse_derived(d)} for n, u, d in rows],
    }


def write_bench_json(path: str, suite: str, rows: List[Row],
                     elapsed_s: float) -> None:
    with open(path, "w") as f:
        json.dump(bench_json(suite, rows, elapsed_s), f, indent=2)
        f.write("\n")
