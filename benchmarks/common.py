"""Shared benchmark helpers."""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Tuple, Union

Row = Tuple[str, float, str]   # (name, us_per_call, derived)


def timed(fn: Callable, *args, n: int = 3, **kw):
    fn(*args, **kw)                      # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / n
    return out, dt * 1e6                 # us


def fmt_rows(rows: List[Row]) -> str:
    return "\n".join(f"{n},{u:.1f},{d}" for n, u, d in rows)


def parse_derived(derived: str) -> Dict[str, Union[float, str]]:
    """'a=12;b=3.4x;note' -> {'a': 12.0, 'b': 3.4, 'note': 'note'}.

    Values parse as floats (a trailing 'x' multiplier is stripped);
    anything else stays a string, bare fragments key themselves.
    """
    out: Dict[str, Union[float, str]] = {}
    for part in filter(None, (p.strip() for p in derived.split(";"))):
        key, sep, val = part.partition("=")
        if not sep:
            out[key] = key
            continue
        try:
            out[key] = float(val[:-1] if val.endswith("x") else val)
        except ValueError:
            out[key] = val
    return out


def env_meta() -> dict:
    """Where this measurement ran: platform, device kind and count.
    Without it the cross-PR BENCH_*.json trajectory silently compares a
    laptop CPU against an 8-way forced-device host or a TPU pod. Rows
    measured on a mesh record their actual topology themselves (a
    `mesh=dataXxmodelY` derived entry) — the topology is a per-row
    choice, not a host fact."""
    import os

    import jax
    devs = jax.devices()
    return {
        "platform": jax.default_backend(),
        "device_kind": devs[0].device_kind,
        "device_count": len(devs),
        # host core count separates otherwise-identical "cpu" entries
        # (a laptop vs a CI runner): the regression guard refuses to
        # compare rounds/sec across different machines
        "cpu_count": os.cpu_count(),
        # XLA_FLAGS changes what was actually measured (forced device
        # counts, compiler knobs), so record it — but only when set:
        # the unset common case must keep env equality with baselines
        # that predate the key
        **({"xla_flags": os.environ["XLA_FLAGS"]}
           if os.environ.get("XLA_FLAGS") else {}),
    }


def bench_json(suite: str, rows: List[Row], elapsed_s: float) -> dict:
    """Machine-readable suite result (one BENCH_<suite>.json per suite):
    us/call (us/round for the round suites) plus every derived metric —
    rounds/sec included — parsed into numbers, and the device
    environment, so the perf trajectory is diffable across PRs. Rows
    measured on a mesh carry their own `mesh=...` derived entry (e.g.
    the sharded-bank rows)."""
    return {
        "suite": suite,
        "elapsed_s": round(elapsed_s, 3),
        "env": env_meta(),
        "rows": [{"name": n, "us_per_call": round(u, 3),
                  "derived": parse_derived(d)} for n, u, d in rows],
    }


def write_bench_json(path: str, suite: str, rows: List[Row],
                     elapsed_s: float) -> None:
    with open(path, "w") as f:
        json.dump(bench_json(suite, rows, elapsed_s), f, indent=2)
        f.write("\n")
