"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]   # (name, us_per_call, derived)


def timed(fn: Callable, *args, n: int = 3, **kw):
    fn(*args, **kw)                      # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / n
    return out, dt * 1e6                 # us


def fmt_rows(rows: List[Row]) -> str:
    return "\n".join(f"{n},{u:.1f},{d}" for n, u, d in rows)
