"""§Roofline table: reads the dry-run artifacts (results/dryrun/*.json) and
emits one row per (arch x shape x mesh) with the three roofline terms, the
dominant bottleneck, and the useful-FLOPs ratio."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def run(mesh: str = None):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh and rec.get("mesh") != mesh:
            continue
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if not rec.get("ok"):
            rows.append((name, 0.0, f"FAILED:{rec.get('error', '?')[:80]}"))
            continue
        r = rec["roofline"]
        us = r["step_lower_bound_s"] * 1e6
        rows.append((name, us,
                     f"compute_s={r['compute_s']:.3g};"
                     f"memory_s={r['memory_s']:.3g};"
                     f"collective_s={r['collective_s']:.3g};"
                     f"dominant={r['dominant']};"
                     f"useful_ratio={r['useful_flops_ratio']:.3g}"))
    if not rows:
        rows.append(("roofline/none", 0.0,
                     "no dry-run artifacts; run repro.launch.dryrun first"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
