"""Chaos harness (PR 8): what the fault layer costs and what faults cost.

Two question families, both on the fused driver:

  * guard_overhead — the fault-armed engine (checksum verification,
    finite guards, quarantine windows in every round) under a ZERO-fault
    plan against the fault-off engine on the identical workload:
    rounds/sec of both and their ratio. The ratio is the price of
    carrying the guards when nothing goes wrong — the regression guard
    pins it (an accidental host sync or a per-round reencode would show
    up here first).
  * degradation_<mech>_<codec>_p<rate> — convergence under injected
    faults: final training loss of a clean run vs a faulted run at fault
    rate p, sweeping mechanism (paper/tree) x bank codec (f32/int8) x
    fault rate. Deterministic seeds end to end, so `loss_ratio`
    (faulty/clean, smaller is better) is a committed trajectory metric,
    not a flaky timing. The fault tallies ride along so a rate change is
    visible next to its cost.

Timings are interleaved medians (engines alternate within each rep) so
machine noise hits both alike.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.federation import (DataOwner, FaultPlan, FaultPolicy, Federation,
                              FederationConfig, LatencyPlan,
                              PrivatizerConfig, StalenessPolicy)

N_OWNERS, DIM, BATCH = 16, 32, 8
POLICY = FaultPolicy(max_faults=8, window=32)
# stale-trace scenario (PR 10): every owner's response time straddles the
# deadline (0.6 + Exp(0.8-mean) vs 1.2), so roughly half the rounds are
# answered late — ages grow between grants and the decayed-inertia knob
# has something to win on
STALE_LAT = LatencyPlan(base=0.6, jitter=0.8)
STALE_DEADLINE = 1.2


def _model():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (DIM, DIM)) / DIM,
              "b": jnp.zeros((DIM,))}

    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)

    return params, loss_fn


def _batches(k):
    return {"x": jax.random.normal(jax.random.PRNGKey(1), (k, BATCH, DIM)),
            "y": jax.random.normal(jax.random.PRNGKey(2), (k, BATCH, DIM))}


def _make_fed(loss_fn, horizon, *, fault_policy=None, bank_dtype=None,
              mechanism="paper", tree_depth=None, staleness=None):
    owners = [DataOwner(n=10_000, epsilon=2.0, xi=1.0)
              for _ in range(N_OWNERS)]
    fed = Federation(owners, FederationConfig(horizon=horizon, sigma=1e-2,
                                              lr_scale=5.0),
                     mechanism=mechanism, tree_depth=tree_depth,
                     fault_policy=fault_policy, staleness=staleness)
    pack = bank_dtype is not None
    fed.make_step(loss_fn, privatizer=PrivatizerConfig(
        xi=1.0, granularity="microbatch", n_microbatches=1),
        pack_params=pack, bank_dtype=bank_dtype)
    return fed


def _time_run(fed, state, batches, owner_seq, root, **kw):
    t0 = time.perf_counter()
    state, _ = fed.run_rounds(state, batches, owner_seq, root, **kw)
    jax.block_until_ready(jax.tree_util.tree_leaves(state.theta_L)[0])
    return time.perf_counter() - t0


def measure_guard_overhead(k: int, reps: int = 9):
    """Interleaved-median seconds for K rounds: fault-off engine vs the
    fault-armed engine under a zero-fault plan (guards fully active,
    nothing faulting — the steady-state healthy path)."""
    params, loss_fn = _model()
    batches = _batches(k)
    owner_seq = jax.random.randint(jax.random.PRNGKey(3), (k,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    fed_p = _make_fed(loss_fn, 4 * k)
    fed_g = _make_fed(loss_fn, 4 * k, fault_policy=POLICY)
    runs = ((fed_p, {}), (fed_g, dict(faults=FaultPlan())))
    # same root key on purpose: warmup and every timed rep must be the
    # IDENTICAL workload on both engines (equivalence is asserted in
    # tests/test_faults.py, not here)
    for fed, kw in runs:                                        # compile
        _time_run(fed, fed.init_state(params), batches,  # dpcheck: ignore[DPC105]
                  owner_seq, root, **kw)
    times = [[], []]
    for _ in range(reps):
        for i, (fed, kw) in enumerate(runs):
            times[i].append(_time_run(  # dpcheck: ignore[DPC105]
                fed, fed.init_state(params), batches, owner_seq, root,
                **kw))
    return float(np.median(times[0])), float(np.median(times[1]))


def measure_degradation(k: int, rate: float, *, bank_dtype=None,
                        mechanism="paper"):
    """Final mean loss over the training batches: clean run vs a faulted
    run at total fault rate `rate` (split evenly over the four codes),
    same schedule/keys. Returns (loss_clean, loss_faulty, tallies)."""
    params, loss_fn = _model()
    batches = _batches(k)
    owner_seq = jax.random.randint(jax.random.PRNGKey(3), (k,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    depth = 4 if mechanism == "tree" else None

    def final_loss(plan):
        fed = _make_fed(loss_fn, 4 * k, fault_policy=POLICY,
                        bank_dtype=bank_dtype, mechanism=mechanism,
                        tree_depth=depth)
        state, m = fed.run_rounds(fed.init_state(params), batches,
                                  owner_seq, root, faults=plan)
        theta = state.theta_L
        if hasattr(theta, "unpack"):
            theta = theta.unpack()
        losses = jax.vmap(lambda b: loss_fn(theta, b))(batches)
        return float(jnp.mean(losses)), m

    loss_clean, _ = final_loss(FaultPlan())
    q = rate / 4.0
    loss_faulty, m = final_loss(FaultPlan(drop=q, stale=q, nonfinite=q,
                                          corrupt=q))
    tallies = {name: int(np.asarray(m[name]).sum())
               for name in ("dropped", "faulted", "quarantined")}
    return loss_clean, loss_faulty, tallies


def measure_retry_overhead(k: int, reps: int = 9):
    """Interleaved-median seconds for K rounds: the fault-armed engine vs
    the staleness-armed engine (deadline comparisons, retry/backoff
    counters, age ticks, decayed inertia) under a fast-enough latency
    plan — the price of carrying the async runtime when (almost) nothing
    is late."""
    params, loss_fn = _model()
    batches = _batches(k)
    owner_seq = jax.random.randint(jax.random.PRNGKey(3), (k,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    fed_g = _make_fed(loss_fn, 4 * k, fault_policy=POLICY)
    fed_s = _make_fed(loss_fn, 4 * k, fault_policy=POLICY,
                      staleness=StalenessPolicy(deadline=STALE_DEADLINE,
                                                max_retries=2, decay=0.9))
    runs = ((fed_g, dict(faults=FaultPlan())),
            (fed_s, dict(faults=FaultPlan(),
                         latency=LatencyPlan(base=0.05, jitter=0.05))))
    for fed, kw in runs:                                        # compile
        _time_run(fed, fed.init_state(params), batches,  # dpcheck: ignore[DPC105]
                  owner_seq, root, **kw)
    times = [[], []]
    for _ in range(reps):
        for i, (fed, kw) in enumerate(runs):
            times[i].append(_time_run(  # dpcheck: ignore[DPC105]
                fed, fed.init_state(params), batches, owner_seq, root,
                **kw))
    return float(np.median(times[0])), float(np.median(times[1]))


def measure_staleness_decay(k: int, decay: float = 0.9):
    """Final mean loss under the stale latency trace: decay=1 (raw
    eq. 5-7 inertia target) vs decay<1 (lambda^age pull toward the
    central iterate). Identical schedule/keys/latency draws, so
    `loss_ratio_decay` (decayed / undecayed, smaller is better) is a
    seed-deterministic trajectory metric. The tallies ride along so the
    timeout/retry pressure behind the ratio stays visible."""
    params, loss_fn = _model()
    batches = _batches(k)
    owner_seq = jax.random.randint(jax.random.PRNGKey(3), (k,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)

    def final_loss(d):
        fed = _make_fed(loss_fn, 4 * k, fault_policy=POLICY,
                        staleness=StalenessPolicy(deadline=STALE_DEADLINE,
                                                  max_retries=2, decay=d))
        state, m = fed.run_rounds(fed.init_state(params), batches,
                                  owner_seq, root, faults=FaultPlan(),
                                  latency=STALE_LAT)
        theta = state.theta_L
        if hasattr(theta, "unpack"):
            theta = theta.unpack()
        losses = jax.vmap(lambda b: loss_fn(theta, b))(batches)
        return float(jnp.mean(losses)), m

    loss_plain, _ = final_loss(1.0)
    loss_decay, m = final_loss(decay)
    tallies = {name: int(np.asarray(m[name]).sum())
               for name in ("timed_out", "retried")}
    return loss_plain, loss_decay, tallies


def overhead_row(dt_plain: float, dt_guarded: float, k: int) -> str:
    return (f"rounds_per_sec_plain={k / dt_plain:.0f};"
            f"rounds_per_sec_guarded={k / dt_guarded:.0f};"
            f"overhead_ratio={dt_guarded / dt_plain:.3f}")


def degradation_row(loss_clean: float, loss_faulty: float,
                    tallies: dict, rate: float) -> str:
    return (f"loss_clean={loss_clean:.5f};loss_faulty={loss_faulty:.5f};"
            f"loss_ratio={loss_faulty / loss_clean:.4f};"
            f"fault_rate={rate};"
            + ";".join(f"n_{n}={v}" for n, v in tallies.items()))


def run(fast: bool = False):
    rows = []
    k = 96 if fast else 256
    reps = 5 if fast else 9
    dt_p, dt_g = measure_guard_overhead(k, reps=reps)
    rows.append((f"chaos/guard_overhead/owners{N_OWNERS}/K{k}",
                 dt_g / k * 1e6, overhead_row(dt_p, dt_g, k)))
    kd = 64 if fast else 192
    sweep = [("paper", None, 0.2), ("paper", "int8", 0.2),
             ("tree", None, 0.2)]
    if not fast:
        sweep += [("paper", None, 0.5), ("paper", "int8", 0.5),
                  ("tree", None, 0.5), ("paper", "fp8", 0.2)]
    for mech, bd, rate in sweep:
        lc, lf, tallies = measure_degradation(kd, rate, bank_dtype=bd,
                                              mechanism=mech)
        codec = bd if isinstance(bd, str) else "f32"
        rows.append((f"chaos/degradation_{mech}_{codec}_p{rate}/K{kd}",
                     0.0, degradation_row(lc, lf, tallies, rate)))
    # staleness runtime (PR 10)
    dt_g, dt_s = measure_retry_overhead(k, reps=reps)
    rows.append((f"chaos/retry_overhead/owners{N_OWNERS}/K{k}",
                 dt_s / k * 1e6,
                 f"rounds_per_sec_fault_armed={k / dt_g:.0f};"
                 f"rounds_per_sec_staleness={k / dt_s:.0f};"
                 f"overhead_ratio={dt_s / dt_g:.3f}"))
    lp, ld, tallies = measure_staleness_decay(kd)
    rows.append((f"chaos/staleness_decay/owners{N_OWNERS}/K{kd}",
                 0.0,
                 f"loss_decay1={lp:.5f};loss_decay09={ld:.5f};"
                 f"loss_ratio_decay={ld / lp:.4f};"
                 + ";".join(f"n_{n}={v}" for n, v in tallies.items())))
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
