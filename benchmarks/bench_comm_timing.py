"""Figs. 3 & 9: asynchronous communication timing — Poisson-clock schedule
(i_k vs k) statistics."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import timed
from repro.federation.clocks import owner_counts, poisson_schedule


def run():
    rows = []
    for N in (3, 86):   # lending (3 banks) / health (86 hospitals)
        sched, us = timed(lambda: jax.block_until_ready(
            poisson_schedule(jax.random.PRNGKey(0), N, 1000)))
        counts = np.asarray(owner_counts(sched.owners, N))
        gaps = np.diff(np.asarray(sched.times))
        rows.append((f"comm_timing/N{N}", us,
                     f"mean_gap={gaps.mean():.4g};expected={1.0/N:.4g};"
                     f"min_count={counts.min()};max_count={counts.max()}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
